"""Serving-subsystem tests: continuous batching through the SOL pipeline.

Covers the ISSUE 5 acceptance surface: scheduler fairness (no request
starves), bucket-padding parity against an unbatched forward at 1e-5,
served elections matching ``impl_report(provenance=True)`` on the same
shapes, the deploy→serve round-trip, and the single-DMA batch staging."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import autotune as AT
from repro.frontends.offload import device
from repro.frontends.optimize import SolModel, optimize
from repro.launch.serve import (ProvenanceError, ServeConfig, SlotArena,
                                SolServer, embedding_table)
from repro.runtime import packed
from repro.runtime.async_queue import AsyncQueue


def tiny_cfg(**kw) -> ServeConfig:
    base = dict(d_model=32, n_heads=2, n_layers=1, vocab=64, max_seq=32,
                max_batch=2, slots=3, backend="xla")
    base.update(kw)
    return ServeConfig(**base)


@pytest.fixture(autouse=True)
def _native_mode_and_local_cache():
    """Native offload mode + a private autotune cache per test, so serving
    elections never leak into (or read from) the process-wide state other
    tests use."""
    device.set("cpu", 0, mode="native")
    prev = AT.get_cache()
    AT.set_cache(AT.AutotuneCache())
    yield
    AT.set_cache(prev)
    device.set("cpu", 0, mode="native")


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------

def test_scheduler_fairness_no_starvation():
    """5 requests over 3 KV slots and a max_batch of 2: every request
    finishes, and while resident no request waits more than
    ceil(slots/max_batch) steps between serves (LRU round-robin bound)."""
    cfg = tiny_cfg(max_seq=16)
    server = SolServer(cfg)
    reqs = [server.submit([1 + i, 2, 3, 4], max_new_tokens=4)
            for i in range(5)]
    server.run()
    assert server.stats["admitted"] == 5
    assert server.stats["evicted"] == 5
    for r in reqs:
        assert r.done and len(r.generated) == 4
        gaps = np.diff(r.served_steps)
        assert gaps.size == 0 or gaps.max() <= 2, \
            f"request {r.rid} starved: served at steps {r.served_steps}"
    server.close()


def test_prefill_and_decode_interleave():
    """Admission happens mid-stream: a request submitted after serving has
    begun gets a freed/free slot and its prefill shares batches with the
    older requests' decode steps."""
    cfg = tiny_cfg(max_seq=16, slots=3)
    server = SolServer(cfg)
    a = server.submit([1, 2, 3], max_new_tokens=6)
    b = server.submit([4, 5], max_new_tokens=6)
    server.step()                       # both prefill
    late = server.submit([6, 7, 8], max_new_tokens=2)
    server.run()
    assert a.done and b.done and late.done
    # the late request was served while a/b were still decoding
    assert late.served_steps[0] <= max(a.served_steps[-1],
                                       b.served_steps[-1])
    assert server.stats["prefills"] == 3
    assert server.stats["decodes"] == server.stats["tokens"] - 3
    server.close()


def test_admission_blocks_when_slots_full():
    cfg = tiny_cfg(max_seq=16, slots=1, max_batch=2)
    server = SolServer(cfg)
    first = server.submit([1, 2], max_new_tokens=3)
    second = server.submit([3, 4], max_new_tokens=3)
    server.step()
    assert first.phase != "pending" and second.phase == "pending"
    assert server.arena.free_slots == 0
    server.run()
    assert first.done and second.done
    # eviction released the slot for the second request
    assert second.served_steps[0] > first.served_steps[-1]
    server.close()


def test_submit_validation():
    server = SolServer(tiny_cfg())
    with pytest.raises(ValueError):
        server.submit([], 4)
    with pytest.raises(ValueError):
        server.submit(list(range(1, 33)), 4)          # no room to decode
    with pytest.raises(ValueError):
        server.submit([999], 4)                       # out of vocab
    server.close()


# ---------------------------------------------------------------------------
# bucket padding ↔ autotune alignment
# ---------------------------------------------------------------------------

def test_ceil_pow2_buckets_are_their_own_cache_bucket():
    for d in (1, 2, 3, 5, 8, 9, 17, 31, 32, 33, 100):
        p = AT.ceil_pow2(d)
        assert p >= d and (p & (p - 1)) == 0
        assert AT.bucket_dim(p) == p        # pow2 is its own bucket
    assert AT.pad_shape((3, 11, 32)) == (4, 16, 32)


def test_bucket_padding_parity_vs_unbatched_forward():
    """A prompt of length 11 served through the padded (1, 16) bucket must
    produce the same next-token logits as an unpadded, unbatched (1, 11)
    forward through the same pipeline — at 1e-5."""
    cfg = tiny_cfg(max_batch=1, slots=1)
    server = SolServer(cfg)
    prompt = (np.arange(1, 12) % cfg.vocab).astype(np.int32)
    req = server.submit(prompt, max_new_tokens=1)
    server.run()
    assert req.done and req.last_logits is not None
    assert "1x16" in server.stats["buckets"]          # served padded

    x = embedding_table(cfg)[prompt][None]            # (1, 11, d_model)
    sol = optimize(server.model, (1, len(prompt), cfg.d_model),
                   backend=cfg.backend)
    ref = np.asarray(sol(jnp.asarray(x)))[0, -1]
    np.testing.assert_allclose(req.last_logits, ref, rtol=1e-5, atol=1e-5)
    server.close()


# ---------------------------------------------------------------------------
# elections + provenance
# ---------------------------------------------------------------------------

def test_served_elections_match_impl_report_with_measured_provenance():
    cfg = tiny_cfg()
    server = SolServer(cfg, strict_provenance=True)
    for i in range(3):
        server.submit([i + 1, 2, 3, 4, 5], max_new_tokens=3)
    counts = server.warm_autotune()
    assert counts["impls"] > 0
    server.run()
    assert server.served_elections
    for bucket, rec in server.served_elections.items():
        model = server._models[bucket]
        assert isinstance(model, SolModel)
        assert model.check_provenance() == []
        rep = model.impl_report(by_kind=True)
        prov = model.impl_report(provenance=True)
        for kind, impls in rec["by_op"].items():
            assert rep[kind] == impls, \
                f"served elections diverge from impl_report for {kind}"
            for name in impls:
                assert set(prov[name]["sources"]) == {"measured"}
    server.close()


def test_strict_provenance_cold_cache_is_loud():
    """With an empty autotune cache a strict server must refuse to serve —
    the 'silent roofline fallback' the smoke run exists to catch."""
    server = SolServer(tiny_cfg(), strict_provenance=True)
    server.submit([1, 2, 3], max_new_tokens=2)
    with pytest.raises(ProvenanceError, match="unmeasured"):
        server.run()
    server.close()


def test_strict_provenance_rejects_nearest_bucket_fallback():
    """'measured' provenance via the cache's nearest-bucket fallback is
    timings from a DIFFERENT shape: a strict server must refuse a bucket
    whose exact shapes were never measured, even when nearby buckets were
    — and an incremental re-warm (which skips covered buckets) unblocks."""
    cfg = tiny_cfg()
    server = SolServer(cfg, strict_provenance=True)
    server.submit([1, 2, 3, 4], max_new_tokens=2)
    server.warm_autotune()                   # covers seq bucket 8 only
    server.submit(list(range(1, 13)), max_new_tokens=2)   # opens seq 16
    with pytest.raises(ProvenanceError, match="nearest-bucket"):
        server.run()
    again = server.warm_autotune()           # warm the new bucket only
    assert again["nodes"] > 0 and again["skipped"] > 0
    server.run()
    assert all(r.done for r in server._finished)
    server.close()


def test_warm_autotune_skips_already_measured_buckets():
    cfg = tiny_cfg()
    server = SolServer(cfg)
    server.submit([1, 2, 3, 4], max_new_tokens=2)
    first = server.warm_autotune(warmup=0, iters=1)
    again = server.warm_autotune(warmup=0, iters=1)
    assert first["nodes"] > 0
    assert again["nodes"] == 0 and again["skipped"] >= first["nodes"]
    server.close()


# ---------------------------------------------------------------------------
# deploy → serve round-trip
# ---------------------------------------------------------------------------

def test_deploy_serve_roundtrip():
    cfg = tiny_cfg(max_seq=16, max_batch=2, slots=2)
    live = SolServer(cfg)
    prompts = [[1, 2, 3], [4, 5, 6, 7], [8, 9]]
    live_reqs = [live.submit(p, max_new_tokens=3) for p in prompts]
    live.run()
    arts = live.export_artifacts()
    assert arts, "live serving compiled no bucket models?"
    assert all(isinstance(b, bytes) for b in arts.values())

    replay = SolServer(cfg, deployed=arts)
    rep_reqs = [replay.submit(p, max_new_tokens=3) for p in prompts]
    replay.run()
    for a, b in zip(live_reqs, rep_reqs):
        assert a.generated == b.generated, \
            f"artifact serving diverged for request {a.rid}"
    # the artifact's election metadata mirrors the live model's report
    for bucket in arts:
        assert (replay._models[bucket].impl_report(by_kind=True)
                == live._models[bucket].impl_report(by_kind=True))
    # a bucket without an artifact is loud, never a silent live compile
    with pytest.raises(KeyError, match="deploy"):
        replay._model_for((8, 8))
    live.close()
    replay.close()


# ---------------------------------------------------------------------------
# staging + arena
# ---------------------------------------------------------------------------

def test_stage_batch_is_one_dma():
    packed.reset_transfer_stats()
    rows = [np.full((8, 4), i, np.float32) for i in range(3)]
    x = packed.stage_batch(rows)
    assert x.shape == (3, 8, 4)
    for i in range(3):
        assert float(np.asarray(x)[i, 0, 0]) == i
    assert packed.TRANSFER_STATS["packed_dmas"] == 1
    assert packed.TRANSFER_STATS["direct_dmas"] == 0
    with pytest.raises(ValueError, match="uniform"):
        packed.stage_batch([np.zeros((2,)), np.zeros((3,))])
    with pytest.raises(ValueError):
        packed.stage_batch([])


def test_serving_uses_one_dma_per_step():
    cfg = tiny_cfg(max_seq=16)
    server = SolServer(cfg)
    for i in range(3):
        server.submit([i + 1, 2, 3], max_new_tokens=2)
    packed.reset_transfer_stats()
    summary = server.run()
    assert summary["dmas"] == summary["steps"]
    assert packed.TRANSFER_STATS["packed_dmas"] == summary["steps"]
    server.close()


def test_slot_arena_admission_eviction_and_pointer_append():
    q = AsyncQueue()
    arena = SlotArena(q, n_slots=2, max_seq=8)
    s0 = arena.admit(np.asarray([5, 6, 7], np.int32))
    s1 = arena.admit(np.asarray([9], np.int32))
    assert arena.admit(np.asarray([1], np.int32)) is None   # full
    arena.append(s0, 42)
    q.synchronize()
    assert arena.tokens(s0).tolist() == [5, 6, 7, 42]
    assert arena.tokens(s1).tolist() == [9]
    arena.evict(s1)
    s2 = arena.admit(np.asarray([2, 3], np.int32))          # slot reused
    assert s2 is not None
    q.synchronize()
    assert arena.tokens(s2).tolist() == [2, 3]
    q.close()


def test_slot_arena_rejects_oversized_prompt():
    q = AsyncQueue()
    arena = SlotArena(q, n_slots=1, max_seq=4)
    with pytest.raises(ValueError, match="exceeds"):
        arena.admit(np.arange(5, dtype=np.int32))
    assert arena.free_slots == 1       # nothing leaked
    q.close()
