#!/usr/bin/env python
"""Check relative markdown links (and their #anchors) in the repo docs.

Stdlib-only, run by CI:

    python tools/check_links.py            # README.md + docs/*.md
    python tools/check_links.py FILE ...   # explicit file list

For every inline link ``[text](target)`` whose target is not an absolute
URL or a bare in-page anchor, the target path is resolved relative to the
containing file and must exist; if the target carries a ``#fragment`` and
points at a markdown file, the fragment must match a heading's GitHub
anchor slug.  Exit non-zero listing every broken link.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

# [text](target) — target up to the first unescaped ')'; ignore images the
# same way as links (the path must exist either way).
LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$")
CODE_FENCE_RE = re.compile(r"^(```|~~~)")


def rel(p: Path) -> str:
    try:
        return str(p.relative_to(ROOT))
    except ValueError:
        return str(p)


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: strip markdown/punctuation, spaces → dashes."""
    text = re.sub(r"[`*_]", "", heading.strip()).lower()
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def heading_anchors(md: Path) -> set:
    anchors = set()
    seen: dict = {}
    in_fence = False
    for line in md.read_text(encoding="utf-8").splitlines():
        if CODE_FENCE_RE.match(line):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        m = HEADING_RE.match(line)
        if not m:
            continue
        slug = github_slug(m.group(1))
        n = seen.get(slug, 0)
        seen[slug] = n + 1
        anchors.add(slug if n == 0 else f"{slug}-{n}")
    return anchors


def check_file(md: Path) -> list:
    errors = []
    in_fence = False
    for lineno, line in enumerate(
            md.read_text(encoding="utf-8").splitlines(), 1):
        if CODE_FENCE_RE.match(line):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for m in LINK_RE.finditer(line):
            target = m.group(1)
            if re.match(r"^[a-z][a-z0-9+.-]*:", target):   # http:, mailto:
                continue
            path_part, _, fragment = target.partition("#")
            if not path_part:                # bare in-page #anchor
                dest = md
            else:
                dest = (md.parent / path_part).resolve()
                if not dest.exists():
                    errors.append(f"{rel(md)}:{lineno}: "
                                  f"missing target {target}")
                    continue
            if fragment and dest.suffix == ".md":
                if fragment not in heading_anchors(dest):
                    errors.append(f"{rel(md)}:{lineno}: "
                                  f"no heading for anchor #{fragment} "
                                  f"in {rel(dest)}")
    return errors


def main(argv) -> int:
    files = ([Path(a).resolve() for a in argv]
             if argv else [ROOT / "README.md", *sorted(ROOT.glob("docs/*.md"))])
    errors = []
    for f in files:
        if not f.exists():
            errors.append(f"{f}: file not found")
            continue
        errors.extend(check_file(f))
    for e in errors:
        print(e, file=sys.stderr)
    print(f"check_links: {len(files)} files, "
          f"{len(errors)} broken link(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
