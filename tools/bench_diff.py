#!/usr/bin/env python3
"""Diff two BENCH_*.json artifacts and gate CI on perf regressions.

Any two artifacts the benchmark harness emits (``BENCH_sol.json``,
``BENCH_matmul.json``, ``BENCH_serve.json``, or the combined
``BENCH_<sha>.json``) share one schema: ``{"rows": [{"name", "us_per_call",
"derived"}]}`` where every ``us_per_call`` is lower-is-better (throughput
rows are encoded as µs/token).  This tool joins the two row sets by name
and fails (exit 1) when any shared row's time regressed by more than
``--threshold`` (default 15%), so speed never silently regresses.

    python tools/bench_diff.py baseline/BENCH_sol.json BENCH_sol.json

CI feeds it the previous run's uploaded artifact; the stdlib-only
implementation keeps it runnable anywhere.  A missing/unreadable baseline
(the first run ever, an expired artifact) or an empty join (tables were
renamed) passes trivially with a "no baseline" note — the gate compares
runs, it must never block the run that creates the first data point.

Exit codes: 0 ok / no baseline, 1 regression past threshold, 2 bad usage.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Optional, Tuple


def load_rows(path: str) -> Optional[Dict[str, float]]:
    """name → us_per_call from one BENCH artifact; None when the file is
    missing or unreadable (the no-baseline case, not an error)."""
    if not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError):
        return None
    rows = doc.get("rows")
    if not isinstance(rows, list):
        return None
    out: Dict[str, float] = {}
    for r in rows:
        try:
            out[str(r["name"])] = float(r["us_per_call"])
        except (KeyError, TypeError, ValueError):
            continue
    return out


def diff(base: Dict[str, float], cur: Dict[str, float], *,
         threshold: float = 0.15, min_us: float = 0.0
         ) -> Tuple[List[tuple], List[tuple]]:
    """(regressions, improvements) over the shared rows: entries are
    (name, base_us, cur_us, rel) with rel = (cur-base)/base.  Rows faster
    than ``min_us`` in BOTH runs are ignored (sub-noise-floor timings
    regress by large relative factors without meaning anything)."""
    regressions, improvements = [], []
    for name in sorted(base.keys() & cur.keys()):
        b, c = base[name], cur[name]
        if b <= 0 or (b < min_us and c < min_us):
            continue
        rel = (c - b) / b
        if rel > threshold:
            regressions.append((name, b, c, rel))
        elif rel < -threshold:
            improvements.append((name, b, c, rel))
    return regressions, improvements


def render(entries: List[tuple], label: str) -> str:
    out = [f"{label} ({len(entries)}):"]
    for name, b, c, rel in entries:
        out.append(f"  {name:60s} {b:10.1f} -> {c:10.1f} us "
                   f"({rel * 100:+.1f}%)")
    return "\n".join(out)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", help="previous run's BENCH_*.json")
    ap.add_argument("current", help="this run's BENCH_*.json")
    ap.add_argument("--threshold", type=float, default=0.15,
                    help="max tolerated relative slowdown per shared row "
                         "(0.15 = 15%%)")
    ap.add_argument("--min-us", type=float, default=0.0,
                    help="ignore rows faster than this in both runs "
                         "(noise floor)")
    args = ap.parse_args(argv)

    cur = load_rows(args.current)
    if cur is None:
        print(f"[bench_diff] current artifact {args.current!r} is missing "
              f"or unreadable", file=sys.stderr)
        return 2
    base = load_rows(args.baseline)
    if base is None:
        print(f"[bench_diff] no baseline at {args.baseline!r} — first run "
              f"passes trivially")
        return 0
    shared = base.keys() & cur.keys()
    if not shared:
        print("[bench_diff] no shared rows between baseline and current — "
              "nothing to gate (tables renamed?)")
        return 0

    regressions, improvements = diff(base, cur, threshold=args.threshold,
                                     min_us=args.min_us)
    print(f"[bench_diff] {len(shared)} shared rows, threshold "
          f"{args.threshold * 100:.0f}%")
    if improvements:
        print(render(improvements, "improvements"))
    if regressions:
        print(render(regressions, "REGRESSIONS"), file=sys.stderr)
        print(f"[bench_diff] FAIL: {len(regressions)} row(s) regressed "
              f"past {args.threshold * 100:.0f}%", file=sys.stderr)
        return 1
    print("[bench_diff] ok: no row regressed past the threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
