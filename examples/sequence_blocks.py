"""Sequence models through the SOL pipeline: transformer, Griffin (RG-LRU)
and RWKV6 blocks extract as graphs, elect per-node kernel flavours via the
dispatch table, and match framework-eager execution.

    PYTHONPATH=src python examples/sequence_blocks.py [backend]

Backend defaults to 'pallas_interpret' so the Pallas flash-attention and
scan kernels are actually elected (interpret mode runs anywhere).
"""
import sys

import numpy as np
import jax.numpy as jnp

sys.path.insert(0, "src")

from repro.frontends import nn
from repro.frontends.optimize import optimize


def main() -> None:
    backend = sys.argv[1] if len(sys.argv) > 1 else "pallas_interpret"
    blocks = [
        ("transformer", nn.transformer_block(64, 4), (2, 32, 64)),
        ("griffin", nn.griffin_block(48), (2, 32, 48)),
        ("rwkv6", nn.rwkv6_block(64, 4), (2, 32, 64)),
    ]
    for name, model, shape in blocks:
        x = np.random.default_rng(0).standard_normal(shape).astype(np.float32)
        sol = optimize(model, shape, backend=backend)
        err = float(np.abs(np.asarray(sol(x))
                           - np.asarray(model(jnp.asarray(x)))).max())
        print(f"== {name} on {backend}: max|Δ| vs eager = {err:.2e}")
        print(f"   graph: {sol.stats()}")
        for op, impls in sorted(sol.impl_report(by_kind=True).items()):
            print(f"   {op:>12}: {impls}")


if __name__ == "__main__":
    main()
