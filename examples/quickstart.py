"""Quickstart — the paper's Listing 1, verbatim flow.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

import sys
sys.path.insert(0, "src")

from repro.frontends import nn
from repro.frontends.optimize import optimize as sol_optimize
from repro.frontends.offload import device as sol_device


def main() -> None:
    # 1. a normal framework model (the paper's py_model)
    py_model = nn.small_cnn(in_ch=3, classes=10)
    x = np.random.randn(1, 3, 32, 32).astype(np.float32)

    # 2. one line: extract → optimize → compile → inject   (paper line 5)
    sol_model = sol_optimize(py_model, (1, 3, 32, 32))

    # 3. parameters stay framework-managed                  (paper line 6)
    sol_model.load_state_dict(py_model.state_dict())

    # 4. run the optimized model                            (paper line 7)
    y = sol_model(x)
    y_ref = py_model(jnp.asarray(x))
    err = float(np.abs(np.asarray(y) - np.asarray(y_ref)).max())
    print(f"SOL output matches framework: max|Δ| = {err:.2e}")
    print(f"graph: {sol_model.stats()}")

    # 5. transparent offloading: pick a device once, inputs stay host-side
    sol_device.set("cpu", 0, mode="transparent")
    y2 = sol_model(x)
    print(f"transparent offload returns host array: {type(y2).__name__}, "
          f"transfers: {sol_device.transfer_stats}")


if __name__ == "__main__":
    main()
