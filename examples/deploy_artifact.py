"""SOL deployment mode (paper Sec. III-C): extract a model into a
framework-free artifact and serve from the artifact alone.

    PYTHONPATH=src python examples/deploy_artifact.py
"""
import sys
sys.path.insert(0, "src")

import numpy as np
import jax.numpy as jnp

from repro.frontends import nn
from repro.frontends import deploy as D
from repro.frontends.optimize import optimize


def main() -> None:
    model = nn.small_cnn()
    sol = optimize(model, (1, 3, 32, 32))
    blob = D.deploy(sol, (1, 3, 32, 32))
    print(f"deployment artifact: {len(blob) / 1024:.0f} KiB "
          f"(StableHLO graph + weights, no framework/SOL dependency)")

    served = D.load(blob)
    x = np.random.randn(1, 3, 32, 32).astype(np.float32)
    y = served(jnp.asarray(x))
    y_ref = sol(x)
    print(f"artifact output matches: "
          f"max|Δ| = {float(np.abs(np.asarray(y) - np.asarray(y_ref)).max()):.2e}")


if __name__ == "__main__":
    main()
