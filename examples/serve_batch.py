"""Serve a small model with batched requests: continuous-batching-style
decode loop over a KV cache, with packed host→device staging of the
request batch (the paper's packed-memcopy mechanism in use).

    PYTHONPATH=src python examples/serve_batch.py [--arch rwkv6-1.6b]
"""
import argparse
import sys
import time
sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.models import backbone as B
from repro.runtime.packed import transfer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-1.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    params = B.init_params(cfg, jax.random.PRNGKey(0))
    max_seq = args.prompt_len + args.gen

    # batched requests arrive as many small host arrays → ONE packed DMA
    host_prompts = [np.random.randint(0, cfg.vocab, (args.prompt_len,),
                                      np.int32) for _ in range(args.batch)]
    staged = transfer(host_prompts)
    prompts = jnp.stack(staged)
    print(f"staged {args.batch} requests via packed transfer")

    decode = jax.jit(
        lambda p, c, t, pos: B.decode_step(cfg, p, c, t, pos),
        donate_argnums=(1,))

    cache = B.init_cache(cfg, args.batch, max_seq)
    logits = None
    t0 = time.perf_counter()
    for t in range(args.prompt_len):
        logits, cache = decode(params, cache, prompts[:, t:t + 1],
                               jnp.asarray(t))
    toks = jnp.argmax(logits[:, -1], -1)[:, None]
    outs = [toks]
    for t in range(args.prompt_len, max_seq - 1):
        logits, cache = decode(params, cache, toks, jnp.asarray(t))
        toks = jnp.argmax(logits[:, -1], -1)[:, None]
        outs.append(toks)
    jax.block_until_ready(toks)
    dt = time.perf_counter() - t0
    gen = np.concatenate([np.asarray(t) for t in outs], axis=1)
    total = args.batch * (max_seq - 1)
    print(f"{cfg.name}: {total} tokens in {dt:.2f}s "
          f"({total / dt:.0f} tok/s on host CPU)")
    for i in range(min(2, args.batch)):
        print(f"  req {i}: …{gen[i, :10].tolist()}")


if __name__ == "__main__":
    main()
