"""Serve batched requests THROUGH the SOL pipeline: continuous batching on
the elected/tuned graph.

Requests are admitted into an AsyncQueue-backed KV-slot arena, padded to
the same pow2 buckets the autotune cache keys on (so served shapes hit
measured timings and pinned Tunable configs), staged host→device with one
packed DMA per step, and decoded by SolModels whose LINEAR/MATMUL/ATTENTION
elections all carry measured provenance.  The second leg replays the same
workload from framework-free deploy artifacts (paper Sec. III-C).

    PYTHONPATH=src python examples/serve_batch.py [--backend pallas_interpret]
"""
import argparse
import sys
sys.path.insert(0, "src")

from repro.core import autotune as AT
from repro.launch.serve import ServeConfig, SolServer, _smoke_workload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="xla")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--gen", type=int, default=8)
    args = ap.parse_args()

    cfg = ServeConfig(d_model=64, n_heads=4, n_layers=2, vocab=128,
                      max_seq=64, max_batch=4, slots=6,
                      backend=args.backend)
    AT.set_cache(AT.AutotuneCache())          # private, in-memory cache
    server = SolServer(cfg, strict_provenance=True)
    workload = _smoke_workload(cfg, args.requests, args.gen)
    for prompt, gen in workload:
        server.submit(prompt, gen)

    counts = server.warm_autotune()
    print(f"warmed autotune cache: {counts['impls']} impl timings over "
          f"{counts['nodes']} (op, shape) keys")
    summary = server.run()
    print(f"{summary['requests']} requests → {summary['tokens']} tokens in "
          f"{summary['steps']} steps ({summary['tokens_per_s']:.1f} tok/s, "
          f"{summary['dmas']} packed DMAs)")
    print(f"latency p50/p99 {summary['latency_ms']['p50']:.0f}/"
          f"{summary['latency_ms']['p99']:.0f} ms, "
          f"ttft p50 {summary['ttft_ms']['p50']:.0f} ms, "
          f"buckets {summary['buckets']}")
    for bucket, rec in sorted(server.served_elections.items()):
        kinds = {k: list(v) for k, v in rec["by_op"].items()}
        print(f"  bucket {bucket}: {kinds}")

    # deployment loop: export every bucket model, serve from the artifacts
    arts = server.export_artifacts()
    replay = SolServer(cfg, deployed=arts, strict_provenance=True)
    reqs = [replay.submit(p, g) for p, g in workload]
    replay.run()
    live = {r.rid: r.generated for r in server._finished}
    same = all(r.generated == live[r.rid] for r in reqs)
    print(f"deploy round-trip over {len(arts)} artifacts: "
          f"{'bit-identical' if same else 'DIVERGED'}")
    server.close()
    replay.close()


if __name__ == "__main__":
    main()
