"""End-to-end driver: train a (reduced) assigned-architecture LM for a few
hundred steps on the full substrate stack — data pipeline, pjit step,
AdamW+ZeRO, async checkpointing, restart-on-failure.

    PYTHONPATH=src python examples/train_lm.py [--arch olmoe-1b-7b] \
        [--steps 200]

(The production-mesh version of this same driver is
``python -m repro.launch.train --arch <id> --production-mesh``.)
"""
import argparse
import sys
sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_smoke
from repro.data import DataConfig, SyntheticTokenDataset
from repro.distributed.steps import (StepOptions, init_train_state,
                                     make_train_step)
from repro.launch.mesh import make_debug_mesh
from repro.models import backbone as B
from repro.runtime import FailureSimulator, run_with_restart


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--inject-failure", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    mesh = make_debug_mesh(1, 1)
    opts = StepOptions(remat=False, zero=True, lr=3e-3,
                       warmup=10, total_steps=args.steps)
    step_fn, _ = make_train_step(mesh, cfg, opts)
    jitted = jax.jit(step_fn, donate_argnums=(0,))
    ds = SyntheticTokenDataset(DataConfig(seed=0, vocab=cfg.vocab,
                                          seq_len=64, global_batch=8))
    ckpt = CheckpointManager("/tmp/repro_example_ckpt", interval=50)
    losses = []

    def one_step(step, state):
        batch = {k: jnp.asarray(v) for k, v in ds.batch(step).items()}
        state, metrics = jitted(state, batch)
        losses.append(float(metrics["loss"]))
        if step % 20 == 0:
            print(f"step {step:4d}  loss {losses[-1]:.4f}  "
                  f"lr {float(metrics['lr']):.2e}")
        return state

    state = init_train_state(cfg, opts, jax.random.PRNGKey(0))
    sim = FailureSimulator(fail_at_steps=[int(args.steps * 0.6)]) \
        if args.inject_failure else None
    with mesh:
        state, report = run_with_restart(one_step, state, args.steps, ckpt,
                                         sim)
    print(f"\n{cfg.name}: loss {np.mean(losses[:10]):.4f} → "
          f"{np.mean(losses[-10:]):.4f} over {args.steps} steps"
          + (f" ({report.restarts} restart(s) survived)"
             if report.restarts else ""))


if __name__ == "__main__":
    main()
